"""Pallas TPU flash attention (GQA + causal + KV-offset for decode).

Canonical three-level grid ``(heads, q_blocks, kv_blocks)`` with the kv axis
innermost (TPU grids execute sequentially minor-to-major, so VMEM scratch
accumulators persist across kv steps): online-softmax running max / sum /
weighted accumulator, finalized on the last kv block.

Causal block skipping: kv blocks entirely above the causal diagonal are
skipped with ``pl.when`` — the same "bound says no work" pattern the guided
traversal kernel uses for pruned tiles.

GQA is expressed in the BlockSpec index maps: kv specs map head ``h`` to
``h // group``, so no KV duplication is materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import default_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i,
            *, block_q: int, block_k: int, sm_scale: float, causal: bool,
            kv_offset: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    # absolute positions: q rows live at kv_offset + qi*block_q + iota
    q_pos = (kv_offset + qi * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
    k_pos = (ki * block_k
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))

    run = True
    if causal:
        # skip blocks entirely above the diagonal
        run = (ki * block_k) <= (kv_offset + qi * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, :]
        k = k_ref[0, :, :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        if causal:
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_i[:, 0], s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        scale = jnp.exp(m_i[:, 0] - m_new)
        l_new = l_i[:, 0] * scale + p.sum(axis=1)
        v = v_ref[0, :, :]
        acc[...] = (acc[...] * scale[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
        m_i[:, 0] = m_new
        l_i[:, 0] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_i[:, 0], 1e-30)
        o_ref[0, :, :] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "sm_scale", "block_q", "block_k", "kv_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    sm_scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, kv_offset: int = 0,
                    interpret: bool | None = None):
    """q: [H, Sq, D]; k, v: [Hkv, Skv, D] with H % Hkv == 0.

    ``kv_offset``: absolute position of q row 0 (decode: cache length).
    Batch dimension: vmap this function.
    ``interpret=None``: native lowering on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    h, sq, d = q.shape
    hkv, skv, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    n_kv = skv // block_k
    kern = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, sm_scale=sm_scale,
        causal=causal, kv_offset=kv_offset, n_kv_blocks=n_kv)
    return pl.pallas_call(
        kern,
        grid=(h, sq // block_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hi, qi, ki: (hi, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda hi, qi, ki: (hi // group, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda hi, qi, ki: (hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hi, qi, ki: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32),
                        pltpu.VMEM((block_q, 1), jnp.float32),
                        pltpu.VMEM((block_q, 1), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
