"""Pallas TPU kernel for the guided tile-scoring hot loop (paper core).

Fuses, entirely in VMEM, the per-tile inner computation of the 2GTI
tile-scan engine:

  1. posting scatter -> dense per-term rows via one-hot MXU matvecs
     (TPU-native scatter: ``w[1,P] @ onehot[P,S_blk]``),
  2. global-level essential-presence masking,
  3. the descending local-pruning freeze loop (beta-combined bound vs
     theta_Lo) with gated accumulation,
  4. the three hybrid combinations Global/Local/Rank.

One pallas_call scores one (query, tile) pair; the grid tiles the docid
axis of the tile in ``block_s`` lanes. The kernel is a pure *executor* in
the planner/executor contract (``core.plan``): the essential partition and
freeze bounds arrive precomputed, theta_Gl never enters the kernel, and
skipped-tile work elision is the caller's job (the tile is never
dispatched); *within* a tile the freeze masks gate the accumulate.

VMEM budget per grid cell (defaults Nq<=32, P<=512, block_s=512, f32):
offs/wb/wl 3 * 32*512*4 = 256 KiB, scratch dense rows 2 * 64 KiB,
one-hot 512*512*4 = 1 MiB  ->  ~1.4 MiB, comfortably under ~16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import default_interpret


def _kernel(scal_ref, ess_ref, pbeta_ref, offs_ref, wb_ref, wl_ref,
            out_ref, dense_b, dense_l, *, nq: int, block_s: int):
    th_lo = scal_ref[0]
    alpha = scal_ref[1]
    beta = scal_ref[2]
    gamma = scal_ref[3]
    base = pl.program_id(0) * block_s
    lane = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)

    # Pass 1: scatter postings to dense rows via one-hot matvec (MXU),
    # accumulating essential presence for the global level.
    def scatter(i, ess_cnt):
        offs = offs_ref[i, :][None, :]                     # [1, P]
        onehot = (offs.T == lane).astype(jnp.float32)      # [P, S_blk]
        db = jnp.dot(wb_ref[i, :][None, :], onehot,
                     preferred_element_type=jnp.float32)
        dl = jnp.dot(wl_ref[i, :][None, :], onehot,
                     preferred_element_type=jnp.float32)
        valid = (offs >= 0).astype(jnp.float32)
        cnt = jnp.dot(valid, onehot, preferred_element_type=jnp.float32)
        dense_b[i, :] = db[0]
        dense_l[i, :] = dl[0]
        return ess_cnt + ess_ref[i] * cnt
    ess_cnt = jax.lax.fori_loop(
        0, nq, scatter, jnp.zeros((1, block_s), jnp.float32))
    survive = (ess_cnt > 0).astype(jnp.float32)

    # Pass 2: descending freeze loop (local level).
    def freeze(j, carry):
        i = nq - 1 - j
        sb, sl, alive = carry
        l_part = beta * sb + (1.0 - beta) * sl
        ok = jnp.where(ess_ref[i] > 0, 1.0,
                       (l_part + pbeta_ref[i] > th_lo).astype(jnp.float32))
        alive = alive * ok
        gate = survive * alive
        sb = sb + gate * dense_b[i, :][None, :]
        sl = sl + gate * dense_l[i, :][None, :]
        return sb, sl, alive
    zero = jnp.zeros((1, block_s), jnp.float32)
    sb, sl, alive = jax.lax.fori_loop(
        0, nq, freeze, (zero, zero, jnp.ones((1, block_s), jnp.float32)))

    out_ref[0, :] = (alpha * sb + (1.0 - alpha) * sl)[0]    # Global
    out_ref[1, :] = (beta * sb + (1.0 - beta) * sl)[0]      # Local
    out_ref[2, :] = (gamma * sb + (1.0 - gamma) * sl)[0]    # RankScore
    out_ref[3, :] = (survive * alive)[0]                    # eval mask
    out_ref[4, :] = survive[0]                              # rank mask


@functools.partial(jax.jit, static_argnames=("tile_size", "block_s",
                                             "interpret"))
def guided_score_tile(offs, wb, wl, essential, prefix_beta, th_lo,
                      alpha, beta, gamma, *, tile_size: int,
                      block_s: int = 512, interpret: bool | None = None):
    """Score one (query, tile) pair. Returns [5, tile_size] (see kernel).

    ``interpret=None`` resolves via :func:`pallas_env.default_interpret`:
    native lowering on TPU backends, Python interpreter elsewhere."""
    if interpret is None:
        interpret = default_interpret()
    nq, p = offs.shape
    block_s = min(block_s, tile_size)
    assert tile_size % block_s == 0
    scal = jnp.stack([th_lo, alpha, beta, gamma]).astype(jnp.float32)
    grid = (tile_size // block_s,)
    kern = functools.partial(_kernel, nq=nq, block_s=block_s)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # scalars
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # essential
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # prefix_beta
            pl.BlockSpec((nq, p), lambda i: (0, 0)),               # offs
            pl.BlockSpec((nq, p), lambda i: (0, 0)),               # wb
            pl.BlockSpec((nq, p), lambda i: (0, 0)),               # wl
        ],
        out_specs=pl.BlockSpec((5, block_s), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((5, tile_size), jnp.float32),
        scratch_shapes=[pltpu.VMEM((nq, block_s), jnp.float32),
                        pltpu.VMEM((nq, block_s), jnp.float32)],
        interpret=interpret,
    )(scal, essential.astype(jnp.float32), prefix_beta.astype(jnp.float32),
      offs, wb, wl)


def _chunk_kernel(scal_ref, ess_ref, pbeta_ref, skip_ref,
                  offs_ref, wb_ref, wl_ref, out_ref, dense_b, dense_l,
                  *, nq: int, block_s: int):
    """One grid cell = (tile-in-chunk, lane block). The per-tile skip
    predicate lives in SMEM and gates the scatter + freeze passes via
    ``pl.when`` — a skipped tile costs a predicate read and one zero-fill
    instead of the full MXU scatter and freeze loop, which is what makes
    chunk-level skipping *real* work elision inside a single pallas_call.
    """
    th_lo = scal_ref[0]
    alpha = scal_ref[1]
    beta = scal_ref[2]
    gamma = scal_ref[3]
    c = pl.program_id(0)
    base = pl.program_id(1) * block_s
    lane = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)

    # Skipped tiles publish all-zero scores and masks: zero masks mean no
    # candidate survives, so the caller's queue merge is a no-op for them.
    out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(skip_ref[c] == 0)
    def _score():
        # Pass 1: scatter postings to dense rows via one-hot matvec (MXU),
        # accumulating essential presence for the global level.
        def scatter(i, ess_cnt):
            offs = offs_ref[0, i, :][None, :]                  # [1, P]
            onehot = (offs.T == lane).astype(jnp.float32)      # [P, S_blk]
            db = jnp.dot(wb_ref[0, i, :][None, :], onehot,
                         preferred_element_type=jnp.float32)
            dl = jnp.dot(wl_ref[0, i, :][None, :], onehot,
                         preferred_element_type=jnp.float32)
            valid = (offs >= 0).astype(jnp.float32)
            cnt = jnp.dot(valid, onehot, preferred_element_type=jnp.float32)
            dense_b[i, :] = db[0]
            dense_l[i, :] = dl[0]
            return ess_cnt + ess_ref[c, i] * cnt
        ess_cnt = jax.lax.fori_loop(
            0, nq, scatter, jnp.zeros((1, block_s), jnp.float32))
        survive = (ess_cnt > 0).astype(jnp.float32)

        # Pass 2: descending freeze loop (local level).
        def freeze(j, carry):
            i = nq - 1 - j
            sb, sl, alive = carry
            l_part = beta * sb + (1.0 - beta) * sl
            ok = jnp.where(ess_ref[c, i] > 0, 1.0,
                           (l_part + pbeta_ref[c, i] > th_lo
                            ).astype(jnp.float32))
            alive = alive * ok
            gate = survive * alive
            sb = sb + gate * dense_b[i, :][None, :]
            sl = sl + gate * dense_l[i, :][None, :]
            return sb, sl, alive
        zero = jnp.zeros((1, block_s), jnp.float32)
        sb, sl, alive = jax.lax.fori_loop(
            0, nq, freeze, (zero, zero, jnp.ones((1, block_s), jnp.float32)))

        out_ref[0, 0, :] = (alpha * sb + (1.0 - alpha) * sl)[0]  # Global
        out_ref[0, 1, :] = (beta * sb + (1.0 - beta) * sl)[0]    # Local
        out_ref[0, 2, :] = (gamma * sb + (1.0 - gamma) * sl)[0]  # RankScore
        out_ref[0, 3, :] = (survive * alive)[0]                  # eval mask
        out_ref[0, 4, :] = survive[0]                            # rank mask


@functools.partial(jax.jit, static_argnames=("tile_size", "block_s",
                                             "interpret"))
def guided_score_chunk(offs, wb, wl, essential, prefix_beta, skip, th_lo,
                       alpha, beta, gamma, *, tile_size: int,
                       block_s: int = 512, interpret: bool | None = None):
    """Score a whole chunk of tiles for one query in one ``pallas_call``.

    Grid = (chunk_tiles, lane blocks): per-tile dispatch overhead is
    amortized over the chunk and the per-tile ``skip`` predicate (int32,
    [C]; nonzero = skip) turns bound-failing tiles into near-free grid
    cells. Inputs are chunk-stacked: offs/wb/wl [C, Nq, P], essential /
    prefix_beta [C, Nq] (per-tile planner outputs derived from the
    *chunk-start* thetas — within the chunk that only loosens pruning,
    so rank-safe configs stay exact). Returns [C, 5, tile_size].
    """
    if interpret is None:
        interpret = default_interpret()
    n_chunk, nq, p = offs.shape
    block_s = min(block_s, tile_size)
    assert tile_size % block_s == 0
    scal = jnp.stack([th_lo, alpha, beta, gamma]).astype(jnp.float32)
    grid = (n_chunk, tile_size // block_s)
    kern = functools.partial(_chunk_kernel, nq=nq, block_s=block_s)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # scalars
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # essential
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # prefix_beta
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # skip
            pl.BlockSpec((1, nq, p), lambda c, s: (c, 0, 0)),      # offs
            pl.BlockSpec((1, nq, p), lambda c, s: (c, 0, 0)),      # wb
            pl.BlockSpec((1, nq, p), lambda c, s: (c, 0, 0)),      # wl
        ],
        out_specs=pl.BlockSpec((1, 5, block_s), lambda c, s: (c, 0, s)),
        out_shape=jax.ShapeDtypeStruct((n_chunk, 5, tile_size), jnp.float32),
        scratch_shapes=[pltpu.VMEM((nq, block_s), jnp.float32),
                        pltpu.VMEM((nq, block_s), jnp.float32)],
        interpret=interpret,
    )(scal, essential.astype(jnp.float32), prefix_beta.astype(jnp.float32),
      skip.astype(jnp.int32), offs, wb, wl)
