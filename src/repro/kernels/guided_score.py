"""Pallas TPU kernel for the guided tile-scoring hot loop (paper core).

Fuses, entirely in VMEM, the per-tile inner computation of the 2GTI
tile-scan engine:

  1. posting scatter -> dense per-term rows via one-hot MXU matvecs
     (TPU-native scatter: ``w[1,P] @ onehot[P,S_blk]``),
  2. global-level essential-presence masking,
  3. the descending local-pruning freeze loop (beta-combined bound vs
     theta_Lo) with gated accumulation,
  4. the three hybrid combinations Global/Local/Rank.

One pallas_call scores one (query, tile) pair; the grid tiles the docid
axis of the tile in ``block_s`` lanes. The kernel is a pure *executor* in
the planner/executor contract (``core.plan``): the essential partition and
freeze bounds arrive precomputed, theta_Gl never enters the kernel, and
skipped-tile work elision is the caller's job (the tile is never
dispatched); *within* a tile the freeze masks gate the accumulate.

VMEM budget per grid cell (defaults Nq<=32, P<=512, block_s=512, f32):
offs/wb/wl 3 * 32*512*4 = 256 KiB, scratch dense rows 2 * 64 KiB,
one-hot 512*512*4 = 1 MiB  ->  ~1.4 MiB, comfortably under ~16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import default_interpret


def _kernel(scal_ref, ess_ref, pbeta_ref, offs_ref, wb_ref, wl_ref,
            out_ref, dense_b, dense_l, *, nq: int, block_s: int):
    th_lo = scal_ref[0]
    alpha = scal_ref[1]
    beta = scal_ref[2]
    gamma = scal_ref[3]
    base = pl.program_id(0) * block_s
    lane = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)

    # Pass 1: scatter postings to dense rows via one-hot matvec (MXU),
    # accumulating essential presence for the global level.
    def scatter(i, ess_cnt):
        offs = offs_ref[i, :][None, :]                     # [1, P]
        onehot = (offs.T == lane).astype(jnp.float32)      # [P, S_blk]
        db = jnp.dot(wb_ref[i, :][None, :], onehot,
                     preferred_element_type=jnp.float32)
        dl = jnp.dot(wl_ref[i, :][None, :], onehot,
                     preferred_element_type=jnp.float32)
        valid = (offs >= 0).astype(jnp.float32)
        cnt = jnp.dot(valid, onehot, preferred_element_type=jnp.float32)
        dense_b[i, :] = db[0]
        dense_l[i, :] = dl[0]
        return ess_cnt + ess_ref[i] * cnt
    ess_cnt = jax.lax.fori_loop(
        0, nq, scatter, jnp.zeros((1, block_s), jnp.float32))
    survive = (ess_cnt > 0).astype(jnp.float32)

    # Pass 2: descending freeze loop (local level).
    def freeze(j, carry):
        i = nq - 1 - j
        sb, sl, alive = carry
        l_part = beta * sb + (1.0 - beta) * sl
        ok = jnp.where(ess_ref[i] > 0, 1.0,
                       (l_part + pbeta_ref[i] > th_lo).astype(jnp.float32))
        alive = alive * ok
        gate = survive * alive
        sb = sb + gate * dense_b[i, :][None, :]
        sl = sl + gate * dense_l[i, :][None, :]
        return sb, sl, alive
    zero = jnp.zeros((1, block_s), jnp.float32)
    sb, sl, alive = jax.lax.fori_loop(
        0, nq, freeze, (zero, zero, jnp.ones((1, block_s), jnp.float32)))

    out_ref[0, :] = (alpha * sb + (1.0 - alpha) * sl)[0]    # Global
    out_ref[1, :] = (beta * sb + (1.0 - beta) * sl)[0]      # Local
    out_ref[2, :] = (gamma * sb + (1.0 - gamma) * sl)[0]    # RankScore
    out_ref[3, :] = (survive * alive)[0]                    # eval mask
    out_ref[4, :] = survive[0]                              # rank mask


@functools.partial(jax.jit, static_argnames=("tile_size", "block_s",
                                             "interpret"))
def guided_score_tile(offs, wb, wl, essential, prefix_beta, th_lo,
                      alpha, beta, gamma, *, tile_size: int,
                      block_s: int = 512, interpret: bool | None = None):
    """Score one (query, tile) pair. Returns [5, tile_size] (see kernel).

    ``interpret=None`` resolves via :func:`pallas_env.default_interpret`:
    native lowering on TPU backends, Python interpreter elsewhere."""
    if interpret is None:
        interpret = default_interpret()
    nq, p = offs.shape
    block_s = min(block_s, tile_size)
    assert tile_size % block_s == 0
    scal = jnp.stack([th_lo, alpha, beta, gamma]).astype(jnp.float32)
    grid = (tile_size // block_s,)
    kern = functools.partial(_kernel, nq=nq, block_s=block_s)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # scalars
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # essential
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # prefix_beta
            pl.BlockSpec((nq, p), lambda i: (0, 0)),               # offs
            pl.BlockSpec((nq, p), lambda i: (0, 0)),               # wb
            pl.BlockSpec((nq, p), lambda i: (0, 0)),               # wl
        ],
        out_specs=pl.BlockSpec((5, block_s), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((5, tile_size), jnp.float32),
        scratch_shapes=[pltpu.VMEM((nq, block_s), jnp.float32),
                        pltpu.VMEM((nq, block_s), jnp.float32)],
        interpret=interpret,
    )(scal, essential.astype(jnp.float32), prefix_beta.astype(jnp.float32),
      offs, wb, wl)


def _chunk_kernel(scal_ref, ess_ref, pbeta_ref, skip_ref,
                  offs_ref, wb_ref, wl_ref, out_ref, dense_b, dense_l,
                  *, nq: int, block_s: int):
    """One grid cell = (tile-in-chunk, lane block). The per-tile skip
    predicate lives in SMEM and gates the scatter + freeze passes via
    ``pl.when`` — a skipped tile costs a predicate read and one zero-fill
    instead of the full MXU scatter and freeze loop, which is what makes
    chunk-level skipping *real* work elision inside a single pallas_call.
    """
    th_lo = scal_ref[0]
    alpha = scal_ref[1]
    beta = scal_ref[2]
    gamma = scal_ref[3]
    c = pl.program_id(0)
    base = pl.program_id(1) * block_s
    lane = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)

    # Skipped tiles publish all-zero scores and masks: zero masks mean no
    # candidate survives, so the caller's queue merge is a no-op for them.
    out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(skip_ref[c] == 0)
    def _score():
        # Pass 1: scatter postings to dense rows via one-hot matvec (MXU),
        # accumulating essential presence for the global level.
        def scatter(i, ess_cnt):
            offs = offs_ref[0, i, :][None, :]                  # [1, P]
            onehot = (offs.T == lane).astype(jnp.float32)      # [P, S_blk]
            db = jnp.dot(wb_ref[0, i, :][None, :], onehot,
                         preferred_element_type=jnp.float32)
            dl = jnp.dot(wl_ref[0, i, :][None, :], onehot,
                         preferred_element_type=jnp.float32)
            valid = (offs >= 0).astype(jnp.float32)
            cnt = jnp.dot(valid, onehot, preferred_element_type=jnp.float32)
            dense_b[i, :] = db[0]
            dense_l[i, :] = dl[0]
            return ess_cnt + ess_ref[c, i] * cnt
        ess_cnt = jax.lax.fori_loop(
            0, nq, scatter, jnp.zeros((1, block_s), jnp.float32))
        survive = (ess_cnt > 0).astype(jnp.float32)

        # Pass 2: descending freeze loop (local level).
        def freeze(j, carry):
            i = nq - 1 - j
            sb, sl, alive = carry
            l_part = beta * sb + (1.0 - beta) * sl
            ok = jnp.where(ess_ref[c, i] > 0, 1.0,
                           (l_part + pbeta_ref[c, i] > th_lo
                            ).astype(jnp.float32))
            alive = alive * ok
            gate = survive * alive
            sb = sb + gate * dense_b[i, :][None, :]
            sl = sl + gate * dense_l[i, :][None, :]
            return sb, sl, alive
        zero = jnp.zeros((1, block_s), jnp.float32)
        sb, sl, alive = jax.lax.fori_loop(
            0, nq, freeze, (zero, zero, jnp.ones((1, block_s), jnp.float32)))

        out_ref[0, 0, :] = (alpha * sb + (1.0 - alpha) * sl)[0]  # Global
        out_ref[0, 1, :] = (beta * sb + (1.0 - beta) * sl)[0]    # Local
        out_ref[0, 2, :] = (gamma * sb + (1.0 - gamma) * sl)[0]  # RankScore
        out_ref[0, 3, :] = (survive * alive)[0]                  # eval mask
        out_ref[0, 4, :] = survive[0]                            # rank mask


@functools.partial(jax.jit, static_argnames=("tile_size", "block_s",
                                             "interpret"))
def guided_score_chunk(offs, wb, wl, essential, prefix_beta, skip, th_lo,
                       alpha, beta, gamma, *, tile_size: int,
                       block_s: int = 512, interpret: bool | None = None):
    """Score a whole chunk of tiles for one query in one ``pallas_call``.

    Grid = (chunk_tiles, lane blocks): per-tile dispatch overhead is
    amortized over the chunk and the per-tile ``skip`` predicate (int32,
    [C]; nonzero = skip) turns bound-failing tiles into near-free grid
    cells. Inputs are chunk-stacked: offs/wb/wl [C, Nq, P], essential /
    prefix_beta [C, Nq] (per-tile planner outputs derived from the
    *chunk-start* thetas — within the chunk that only loosens pruning,
    so rank-safe configs stay exact). Returns [C, 5, tile_size].
    """
    if interpret is None:
        interpret = default_interpret()
    n_chunk, nq, p = offs.shape
    block_s = min(block_s, tile_size)
    assert tile_size % block_s == 0
    scal = jnp.stack([th_lo, alpha, beta, gamma]).astype(jnp.float32)
    grid = (n_chunk, tile_size // block_s)
    kern = functools.partial(_chunk_kernel, nq=nq, block_s=block_s)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # scalars
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # essential
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # prefix_beta
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # skip
            pl.BlockSpec((1, nq, p), lambda c, s: (c, 0, 0)),      # offs
            pl.BlockSpec((1, nq, p), lambda c, s: (c, 0, 0)),      # wb
            pl.BlockSpec((1, nq, p), lambda c, s: (c, 0, 0)),      # wl
        ],
        out_specs=pl.BlockSpec((1, 5, block_s), lambda c, s: (c, 0, s)),
        out_shape=jax.ShapeDtypeStruct((n_chunk, 5, tile_size), jnp.float32),
        scratch_shapes=[pltpu.VMEM((nq, block_s), jnp.float32),
                        pltpu.VMEM((nq, block_s), jnp.float32)],
        interpret=interpret,
    )(scal, essential.astype(jnp.float32), prefix_beta.astype(jnp.float32),
      skip.astype(jnp.int32), offs, wb, wl)


# ---------------------------------------------------------------------------
# Decode-in-kernel variants for the compressed index (q8 gather kind).
#
# Inputs arrive *undecoded* (``repro.index.gather_tile_q_raw``): packed
# delta words, raw uint8 impact codes, per-row run metadata. Grid cell 0
# (lane block 0) delta-decodes the offsets and dequantizes both impact
# channels once into VMEM scratch — TPU grid cells run sequentially and
# scratch persists, so later lane blocks reuse the decoded rows. The
# gather is memory-bound, so the decode rides otherwise-idle compute:
#
#   gap_j   = (words[bitpos >> 5] >> (bitpos & 31)) & (2^w - 1)
#             via a one-hot MXU word gather on uint16 halves (each half
#             < 2^16 is exact in f32; recombined in int32),
#   offs_j  = first + sum_{i <= j} (gap_i + 1)   (inclusive-cumsum matmul
#             against a lower-triangular ones matrix — offsets < tile_size
#             <= 2^16 stay exact in f32),
#   w_j     = (zero + scale * q_j) * qw           (<= exact tile max * qw
#             by codec construction, so planner bounds stay valid).
#
# Output gains a 6th row — per-slot posting count — so the caller derives
# presence/postings-touched stats without a second (host-side) decode.
# ---------------------------------------------------------------------------


def _decode_rows(offs_s, wb_s, wl_s, meta_i, meta_f, qw, words, qb, ql,
                 *, nq: int, pad_len: int, wp: int):
    """Decode all ``nq`` rows of one tile into the scratch buffers.

    Accessors (callables, so the single-tile and chunk kernels can bind
    their different block ranks): ``meta_i(r, i)``/``meta_f(r, i)``/
    ``qw(r, i)`` scalar reads, ``words(i)`` -> [Wp] int32,
    ``qb(i)``/``ql(i)`` -> [P] f32 raw codes."""
    j = jax.lax.broadcasted_iota(jnp.int32, (1, pad_len), 1)
    word_iota = jax.lax.broadcasted_iota(jnp.int32, (wp, pad_len), 0)
    # inclusive-cumsum operator: tri[a, b] = 1 iff a <= b
    tri = (jax.lax.broadcasted_iota(jnp.int32, (pad_len, pad_len), 0)
           <= jax.lax.broadcasted_iota(jnp.int32, (pad_len, pad_len), 1)
           ).astype(jnp.float32)

    def dec(i, _):
        cnt_i = meta_i(0, i)
        first_i = meta_i(1, i)
        w_i = meta_i(2, i)
        bitpos = jnp.maximum(j - 1, 0) * w_i            # value idx = j - 1
        widx = jnp.minimum(bitpos >> 5, wp - 1)         # [1, P]
        w32 = words(i)[None, :]                         # [1, Wp] int32
        lo = (w32 & 0xFFFF).astype(jnp.float32)
        hi = jax.lax.shift_right_logical(w32, 16).astype(jnp.float32)
        onehot = (word_iota == widx).astype(jnp.float32)  # [Wp, P]
        lo_j = jnp.dot(lo, onehot, preferred_element_type=jnp.float32)
        hi_j = jnp.dot(hi, onehot, preferred_element_type=jnp.float32)
        word_j = (hi_j.astype(jnp.int32) << 16) | lo_j.astype(jnp.int32)
        shift = bitpos & 31
        gap = (jax.lax.shift_right_logical(word_j, shift)
               & ((1 << w_i) - 1))                      # [1, P]
        contrib = jnp.where(j == 0, first_i, gap + 1).astype(jnp.float32)
        offs_f = jnp.dot(contrib, tri, preferred_element_type=jnp.float32)
        valid = j < cnt_i
        offs_s[i, :] = jnp.where(valid, offs_f.astype(jnp.int32), -1)[0]
        vmask = valid[0].astype(jnp.float32)
        wb_s[i, :] = (meta_f(0, i) + meta_f(1, i) * qb(i)) * vmask * qw(0, i)
        wl_s[i, :] = (meta_f(2, i) + meta_f(3, i) * ql(i)) * vmask * qw(1, i)
        return 0
    jax.lax.fori_loop(0, nq, dec, 0)


def _kernel_q(scal_ref, ess_ref, pbeta_ref, meta_i_ref, meta_f_ref, qw_ref,
              words_ref, qb_ref, ql_ref, out_ref,
              dense_b, dense_l, offs_s, wb_s, wl_s,
              *, nq: int, block_s: int, pad_len: int, wp: int):
    th_lo = scal_ref[0]
    alpha = scal_ref[1]
    beta = scal_ref[2]
    gamma = scal_ref[3]

    @pl.when(pl.program_id(0) == 0)
    def _decode():
        _decode_rows(offs_s, wb_s, wl_s,
                     lambda r, i: meta_i_ref[r, i],
                     lambda r, i: meta_f_ref[r, i],
                     lambda r, i: qw_ref[r, i],
                     lambda i: words_ref[i, :],
                     lambda i: qb_ref[i, :],
                     lambda i: ql_ref[i, :],
                     nq=nq, pad_len=pad_len, wp=wp)

    base = pl.program_id(0) * block_s
    lane = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)

    # Pass 1: scatter decoded postings to dense rows (one-hot MXU matvec),
    # accumulating essential presence and the per-slot posting count.
    def scatter(i, carry):
        ess_cnt, tot_cnt = carry
        offs = offs_s[i, :][None, :]                       # [1, P]
        onehot = (offs.T == lane).astype(jnp.float32)      # [P, S_blk]
        db = jnp.dot(wb_s[i, :][None, :], onehot,
                     preferred_element_type=jnp.float32)
        dl = jnp.dot(wl_s[i, :][None, :], onehot,
                     preferred_element_type=jnp.float32)
        valid = (offs >= 0).astype(jnp.float32)
        cnt = jnp.dot(valid, onehot, preferred_element_type=jnp.float32)
        dense_b[i, :] = db[0]
        dense_l[i, :] = dl[0]
        return ess_cnt + ess_ref[i] * cnt, tot_cnt + cnt
    zero = jnp.zeros((1, block_s), jnp.float32)
    ess_cnt, tot_cnt = jax.lax.fori_loop(0, nq, scatter, (zero, zero))
    survive = (ess_cnt > 0).astype(jnp.float32)

    # Pass 2: descending freeze loop (local level) — identical to _kernel.
    def freeze(j, carry):
        i = nq - 1 - j
        sb, sl, alive = carry
        l_part = beta * sb + (1.0 - beta) * sl
        ok = jnp.where(ess_ref[i] > 0, 1.0,
                       (l_part + pbeta_ref[i] > th_lo).astype(jnp.float32))
        alive = alive * ok
        gate = survive * alive
        sb = sb + gate * dense_b[i, :][None, :]
        sl = sl + gate * dense_l[i, :][None, :]
        return sb, sl, alive
    sb, sl, alive = jax.lax.fori_loop(
        0, nq, freeze, (zero, zero, jnp.ones((1, block_s), jnp.float32)))

    out_ref[0, :] = (alpha * sb + (1.0 - alpha) * sl)[0]    # Global
    out_ref[1, :] = (beta * sb + (1.0 - beta) * sl)[0]      # Local
    out_ref[2, :] = (gamma * sb + (1.0 - gamma) * sl)[0]    # RankScore
    out_ref[3, :] = (survive * alive)[0]                    # eval mask
    out_ref[4, :] = survive[0]                              # rank mask
    out_ref[5, :] = tot_cnt[0]                              # postings/slot


@functools.partial(jax.jit, static_argnames=("tile_size", "pad_len",
                                             "block_s", "interpret"))
def guided_score_tile_q(words, qb_row, ql_row, meta_i, meta_f, qw_b, qw_l,
                        essential, prefix_beta, th_lo, alpha, beta, gamma,
                        *, tile_size: int, pad_len: int, block_s: int = 512,
                        interpret: bool | None = None):
    """Decode-in-kernel scoring of one (query, tile) pair on the
    compressed index. Returns [6, tile_size] — rows 0-4 as
    ``guided_score_tile``, row 5 = per-slot posting count (stats source).

    Inputs are the raw rows from ``repro.index.gather_tile_q_raw`` plus
    the per-term query weights (applied after dequantization, preserving
    the fp32 path's ``fl(dequant) * qw <= fl(tile_max * qw)`` bound)."""
    if interpret is None:
        interpret = default_interpret()
    nq, wp = words.shape
    block_s = min(block_s, tile_size)
    assert tile_size % block_s == 0
    scal = jnp.stack([th_lo, alpha, beta, gamma]).astype(jnp.float32)
    qw = jnp.stack([qw_b, qw_l]).astype(jnp.float32)         # [2, Nq]
    grid = (tile_size // block_s,)
    kern = functools.partial(_kernel_q, nq=nq, block_s=block_s,
                             pad_len=pad_len, wp=wp)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # scalars
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # essential
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # prefix_beta
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # meta_i
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # meta_f
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # qw
            pl.BlockSpec((nq, wp), lambda i: (0, 0)),              # words
            pl.BlockSpec((nq, pad_len), lambda i: (0, 0)),         # qb codes
            pl.BlockSpec((nq, pad_len), lambda i: (0, 0)),         # ql codes
        ],
        out_specs=pl.BlockSpec((6, block_s), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((6, tile_size), jnp.float32),
        scratch_shapes=[pltpu.VMEM((nq, block_s), jnp.float32),
                        pltpu.VMEM((nq, block_s), jnp.float32),
                        pltpu.VMEM((nq, pad_len), jnp.int32),
                        pltpu.VMEM((nq, pad_len), jnp.float32),
                        pltpu.VMEM((nq, pad_len), jnp.float32)],
        interpret=interpret,
    )(scal, essential.astype(jnp.float32), prefix_beta.astype(jnp.float32),
      meta_i.astype(jnp.int32), meta_f.astype(jnp.float32), qw,
      words, qb_row, ql_row)


def _chunk_kernel_q(scal_ref, ess_ref, pbeta_ref, skip_ref, meta_i_ref,
                    meta_f_ref, qw_ref, words_ref, qb_ref, ql_ref, out_ref,
                    dense_b, dense_l, offs_s, wb_s, wl_s,
                    *, nq: int, block_s: int, pad_len: int, wp: int):
    """Chunked decode-in-kernel scoring. Grid = (tile-in-chunk, lane
    block); the grid iterates lane blocks innermost, so decoding tile c's
    rows at lane block 0 leaves the scratch valid for the remaining lane
    blocks of the same tile. Skipped tiles publish zeros and skip both
    the decode and the score passes."""
    th_lo = scal_ref[0]
    alpha = scal_ref[1]
    beta = scal_ref[2]
    gamma = scal_ref[3]
    c = pl.program_id(0)

    out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when((skip_ref[c] == 0) & (pl.program_id(1) == 0))
    def _decode():
        _decode_rows(offs_s, wb_s, wl_s,
                     lambda r, i: meta_i_ref[c, r, i],
                     lambda r, i: meta_f_ref[c, r, i],
                     lambda r, i: qw_ref[r, i],
                     lambda i: words_ref[0, i, :],
                     lambda i: qb_ref[0, i, :],
                     lambda i: ql_ref[0, i, :],
                     nq=nq, pad_len=pad_len, wp=wp)

    base = pl.program_id(1) * block_s
    lane = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)

    @pl.when(skip_ref[c] == 0)
    def _score():
        def scatter(i, carry):
            ess_cnt, tot_cnt = carry
            offs = offs_s[i, :][None, :]
            onehot = (offs.T == lane).astype(jnp.float32)
            db = jnp.dot(wb_s[i, :][None, :], onehot,
                         preferred_element_type=jnp.float32)
            dl = jnp.dot(wl_s[i, :][None, :], onehot,
                         preferred_element_type=jnp.float32)
            valid = (offs >= 0).astype(jnp.float32)
            cnt = jnp.dot(valid, onehot, preferred_element_type=jnp.float32)
            dense_b[i, :] = db[0]
            dense_l[i, :] = dl[0]
            return ess_cnt + ess_ref[c, i] * cnt, tot_cnt + cnt
        zero = jnp.zeros((1, block_s), jnp.float32)
        ess_cnt, tot_cnt = jax.lax.fori_loop(0, nq, scatter, (zero, zero))
        survive = (ess_cnt > 0).astype(jnp.float32)

        def freeze(j, carry):
            i = nq - 1 - j
            sb, sl, alive = carry
            l_part = beta * sb + (1.0 - beta) * sl
            ok = jnp.where(ess_ref[c, i] > 0, 1.0,
                           (l_part + pbeta_ref[c, i] > th_lo
                            ).astype(jnp.float32))
            alive = alive * ok
            gate = survive * alive
            sb = sb + gate * dense_b[i, :][None, :]
            sl = sl + gate * dense_l[i, :][None, :]
            return sb, sl, alive
        sb, sl, alive = jax.lax.fori_loop(
            0, nq, freeze, (zero, zero, jnp.ones((1, block_s), jnp.float32)))

        out_ref[0, 0, :] = (alpha * sb + (1.0 - alpha) * sl)[0]
        out_ref[0, 1, :] = (beta * sb + (1.0 - beta) * sl)[0]
        out_ref[0, 2, :] = (gamma * sb + (1.0 - gamma) * sl)[0]
        out_ref[0, 3, :] = (survive * alive)[0]
        out_ref[0, 4, :] = survive[0]
        out_ref[0, 5, :] = tot_cnt[0]


@functools.partial(jax.jit, static_argnames=("tile_size", "pad_len",
                                             "block_s", "interpret"))
def guided_score_chunk_q(words, qb_row, ql_row, meta_i, meta_f, qw_b, qw_l,
                         essential, prefix_beta, skip, th_lo,
                         alpha, beta, gamma, *, tile_size: int, pad_len: int,
                         block_s: int = 512, interpret: bool | None = None):
    """Chunked decode-in-kernel scoring on the compressed index.

    Chunk-stacked raw inputs (words [C, Nq, Wp], codes [C, Nq, P], meta_i
    [C, 3, Nq], meta_f [C, 4, Nq]); per-tile planner inputs as
    ``guided_score_chunk``. Returns [C, 6, tile_size] (row 5 = per-slot
    posting count)."""
    if interpret is None:
        interpret = default_interpret()
    n_chunk, nq, wp = words.shape
    block_s = min(block_s, tile_size)
    assert tile_size % block_s == 0
    scal = jnp.stack([th_lo, alpha, beta, gamma]).astype(jnp.float32)
    qw = jnp.stack([qw_b, qw_l]).astype(jnp.float32)
    grid = (n_chunk, tile_size // block_s)
    kern = functools.partial(_chunk_kernel_q, nq=nq, block_s=block_s,
                             pad_len=pad_len, wp=wp)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # scalars
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # essential
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # prefix_beta
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # skip
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # meta_i
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # meta_f
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # qw
            pl.BlockSpec((1, nq, wp), lambda c, s: (c, 0, 0)),     # words
            pl.BlockSpec((1, nq, pad_len), lambda c, s: (c, 0, 0)),  # qb
            pl.BlockSpec((1, nq, pad_len), lambda c, s: (c, 0, 0)),  # ql
        ],
        out_specs=pl.BlockSpec((1, 6, block_s), lambda c, s: (c, 0, s)),
        out_shape=jax.ShapeDtypeStruct((n_chunk, 6, tile_size), jnp.float32),
        scratch_shapes=[pltpu.VMEM((nq, block_s), jnp.float32),
                        pltpu.VMEM((nq, block_s), jnp.float32),
                        pltpu.VMEM((nq, pad_len), jnp.int32),
                        pltpu.VMEM((nq, pad_len), jnp.float32),
                        pltpu.VMEM((nq, pad_len), jnp.float32)],
        interpret=interpret,
    )(scal, essential.astype(jnp.float32), prefix_beta.astype(jnp.float32),
      skip.astype(jnp.int32), meta_i.astype(jnp.int32),
      meta_f.astype(jnp.float32), qw, words, qb_row, ql_row)
