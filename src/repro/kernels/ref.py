"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def guided_score_tile_ref(offs, wb, wl, essential, prefix_beta, th_lo,
                          alpha, beta, gamma, *, tile_size: int):
    """Oracle for kernels.guided_score.guided_score_tile -> [5, tile_size]."""
    nq, p = offs.shape
    S = tile_size
    valid = offs >= 0
    offs_safe = jnp.where(valid, offs, S).astype(jnp.int32)
    seg = (jnp.arange(nq, dtype=jnp.int32)[:, None] * (S + 1) + offs_safe
           ).ravel()
    dense_b = jax.ops.segment_sum(
        (wb * valid).ravel(), seg, num_segments=nq * (S + 1)
    ).reshape(nq, S + 1)[:, :S]
    dense_l = jax.ops.segment_sum(
        (wl * valid).ravel(), seg, num_segments=nq * (S + 1)
    ).reshape(nq, S + 1)[:, :S]
    cnt = jax.ops.segment_sum(
        valid.ravel().astype(jnp.float32), seg, num_segments=nq * (S + 1)
    ).reshape(nq, S + 1)[:, :S]
    ess = essential.astype(jnp.float32)
    survive = (jnp.einsum("t,ts->s", ess, cnt) > 0)

    def body(j, carry):
        i = nq - 1 - j
        sb, sl, alive = carry
        l_part = beta * sb + (1 - beta) * sl
        ok = (ess[i] > 0) | (l_part + prefix_beta[i] > th_lo)
        alive = alive & ok
        gate = (survive & alive).astype(jnp.float32)
        return sb + gate * dense_b[i], sl + gate * dense_l[i], alive

    zero = jnp.zeros(S, jnp.float32)
    sb, sl, alive = jax.lax.fori_loop(0, nq, body,
                                      (zero, zero, jnp.ones(S, bool)))
    return jnp.stack([
        alpha * sb + (1 - alpha) * sl,
        beta * sb + (1 - beta) * sl,
        gamma * sb + (1 - gamma) * sl,
        (survive & alive).astype(jnp.float32),
        survive.astype(jnp.float32),
    ])


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sm_scale: float | None = None, kv_offset: int = 0):
    """Oracle for kernels.flash_attention (GQA + causal + offset)."""
    h, sq, d = q.shape
    hkv, skv, _ = k.shape
    group = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kg = jnp.repeat(k, group, axis=0)
    vg = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kg.astype(jnp.float32)) * sm_scale
    if causal:
        q_pos = kv_offset + jnp.arange(sq)[:, None]
        k_pos = jnp.arange(skv)[None, :]
        s = jnp.where(q_pos >= k_pos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p,
                      vg.astype(jnp.float32)).astype(q.dtype)


def embedding_bag_ref(table, indices, weights):
    """Oracle for kernels.embedding_bag: weighted bag sum via take."""
    rows = jnp.take(table, indices, axis=0)        # [B, L, D]
    return (rows * weights[..., None]).sum(axis=1).astype(table.dtype)
