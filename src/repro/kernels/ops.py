"""Jit'd public wrappers for the Pallas kernels.

Every kernel takes ``interpret=None`` and resolves it per process via
``pallas_env.default_interpret``: native lowering when the default
backend is a TPU, the Python interpreter elsewhere. Override both ways
with ``REPRO_PALLAS_COMPILE=1`` (force native) / ``=0`` (force
interpreter).
"""
from __future__ import annotations

from .embedding_bag import embedding_bag  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .guided_score import guided_score_chunk, guided_score_tile  # noqa: F401
from .pallas_env import default_interpret  # noqa: F401
