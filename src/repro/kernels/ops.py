"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True everywhere in this repo (CPU container);
on a real TPU deployment set ``REPRO_PALLAS_COMPILE=1`` to lower natively.
"""
from __future__ import annotations

import os

from .embedding_bag import embedding_bag  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .guided_score import guided_score_tile  # noqa: F401

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"
