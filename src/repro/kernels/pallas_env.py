"""Pallas execution-mode selection shared by every kernel in this package.

``interpret=None`` (the kernels' default) resolves per process:

  - ``REPRO_PALLAS_COMPILE=1``  -> native lowering, ``=0`` -> interpreter
    (explicit override, both directions);
  - otherwise native iff the default backend is a real TPU — CPU/GPU
    containers fall back to the Python interpreter, TPU deployments lower
    natively instead of silently running the emulator.
"""
from __future__ import annotations

import os


def default_interpret() -> bool:
    """True = run kernels under the Pallas interpreter (non-TPU backends)."""
    env = os.environ.get("REPRO_PALLAS_COMPILE")
    if env is not None:
        return env != "1"
    import jax
    return jax.default_backend() != "tpu"
